"""Paged KV cache + shared-prefix reuse (engine/serving paged layout).

The contract under test: greedy tokens from the paged arena are BITWISE
identical to the dense slotted cache — across attention families, under
slot churn, under shared-prefix reuse, under pool pressure (preemption
by recompute) and copy-on-write — while page churn never retraces the
decode step. fp32 compute keeps every comparison exact on CPU.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, get_reduced
from repro.engine import EngineConfig, GenerationRequest, ServeEngine
from repro.engine.serving import PagePool, PrefixIndex
from repro.engine.serving.slots import (dense_kv_bytes, paged_kv_page_bytes)
from repro.models import build_model

TINY = ModelConfig("paged-tiny", "dense", 2, 64, 4, 2, 128, 257,
                   head_dim=16)


def tiny_model():
    return build_model(TINY, compute_dtype=jnp.float32, attn_chunk=16)


def reduced_model(arch):
    cfg = get_reduced(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return build_model(cfg, compute_dtype=jnp.float32, attn_chunk=8)


def run_engine(model, params, reqs, *, stagger=1, **cfg_kw):
    """Staggered arrivals (`stagger` ticks apart — the continuous-
    batching shape, and what lets later requests match prefixes the
    earlier ones registered), then drain."""
    cfg_kw.setdefault("max_slots", 2)
    cfg_kw.setdefault("max_len", 48)
    eng = ServeEngine(EngineConfig(**cfg_kw), model, None, params)
    handles = []
    for r in reqs:
        handles.append(eng.submit(GenerationRequest(**r)))
        for _ in range(stagger):
            eng.step()
    eng.drain()
    return eng, [h.tokens for h in handles]


# ------------------------------------------------- dense-vs-paged bitwise
class TestDenseVsPaged:
    """One engine run per layout, identical staggered workload, token
    streams compared bitwise — the core paging contract."""

    CASES = {
        "gqa": "qwen3-32b",
        "swa": "mixtral-8x22b",      # rolling-window pages
        "mla": "minicpm3-4b",        # paged latent arena
        "hybrid": "hymba-1.5b",      # paged attn + dense mamba state
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_tokens_bitwise_equal(self, name):
        model = reduced_model(self.CASES[name])
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(0)
        V = model.cfg.vocab_size
        reqs = [dict(prompt=rng.randint(0, V, n), max_new_tokens=g)
                for n, g in [(7, 6), (13, 9), (19, 4)]]
        _, dense = run_engine(model, params, reqs, kv_layout="dense")
        eng, paged = run_engine(model, params, reqs, kv_layout="paged")
        assert eng.paged
        assert paged == dense

    def test_swa_prompt_longer_than_window_rolls_pages(self):
        model = reduced_model("mixtral-8x22b")
        w = model.cfg.sliding_window
        params = model.init(jax.random.key(1))
        rng = np.random.RandomState(1)
        reqs = [dict(prompt=rng.randint(0, model.cfg.vocab_size, w + 7),
                     max_new_tokens=6)]
        kw = dict(max_len=w + 32, max_slots=2)
        _, dense = run_engine(model, params, reqs, kv_layout="dense", **kw)
        _, paged = run_engine(model, params, reqs, kv_layout="paged", **kw)
        assert paged == dense

    def test_rwkv_dense_fallback_is_loud(self):
        """An ssm arch under kv_layout='paged' serves dense — and SAYS
        so: EngineWarning at build, dense_fallback_* in kv_stats."""
        from repro.engine.build import EngineWarning

        model = reduced_model("rwkv6-7b")      # no KV to page
        params = model.init(jax.random.key(0))
        with pytest.warns(EngineWarning, match="no attention K/V to page"):
            eng, toks = run_engine(model, params,
                                   [dict(prompt=list(range(1, 8)),
                                         max_new_tokens=4)],
                                   kv_layout="paged")
        assert not eng.paged and len(toks[0]) == 4
        stats = eng.kv_stats()
        assert stats["kv_layout"] == "dense"
        assert stats["dense_fallback_leaves"] > 0
        assert stats["dense_fallback_bytes"] > 0

    def test_hybrid_partial_fallback_reported(self):
        """A hybrid (paged attention + dense mamba state) pages fine but
        reports the leaves that stay dense per-slot."""
        from repro.engine.build import EngineWarning

        model = reduced_model("hymba-1.5b")
        params = model.init(jax.random.key(0))
        with pytest.warns(EngineWarning, match="stay[\\s\\S]*dense per-slot"):
            eng, toks = run_engine(model, params,
                                   [dict(prompt=list(range(1, 8)),
                                         max_new_tokens=4)],
                                   kv_layout="paged")
        assert eng.paged and len(toks[0]) == 4
        assert eng.kv_stats()["dense_fallback_leaves"] > 0

    def test_page_size_must_divide_swa_window(self):
        model = reduced_model("mixtral-8x22b")     # window 32
        params = model.init(jax.random.key(0))
        cfg = EngineConfig(max_slots=2, max_len=48, page_size=24,
                           kv_layout="paged")
        with pytest.raises(ValueError, match="page size dividing"):
            ServeEngine(cfg, model, None, params)

    def test_no_retrace_under_page_churn(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(2)
        reqs = [dict(prompt=rng.randint(0, 257, n), max_new_tokens=g)
                for n, g in [(21, 8), (5, 12), (33, 3), (9, 9)]]
        eng, _ = run_engine(model, params, reqs, stagger=2,
                            kv_layout="paged", max_slots=2, max_len=48)
        assert eng.throughput()["completed"] == 4
        size = getattr(eng._decode, "_cache_size", lambda: 1)()
        assert size == 1, f"decode retraced {size} times"


# ----------------------------------------------------- shared prefixes
class TestSharedPrefix:
    def _prompts(self, sys_len=37, tails=(5, 9, 3), seed=3):
        rng = np.random.RandomState(seed)
        sys_prompt = rng.randint(0, 257, sys_len)
        return [np.concatenate([sys_prompt, rng.randint(0, 257, t)])
                for t in tails]

    def test_shared_prefix_tokens_equal_unshared(self):
        """Requests sharing a system prompt, admitted across ticks, reuse
        its pages read-only and prefill only the unshared tail — with
        tokens bitwise-equal to the dense engine."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        reqs = [dict(prompt=p, max_new_tokens=8) for p in self._prompts()]
        _, dense = run_engine(model, params, reqs, stagger=3,
                              kv_layout="dense", max_slots=4, max_len=64)
        eng, shared = run_engine(model, params, reqs, stagger=3,
                                 kv_layout="paged", max_slots=4, max_len=64)
        assert shared == dense
        # 37-token system prompt = 2 full pages; requests 2 and 3 hit
        assert eng.stats["prefix_hits"] == 2
        assert eng.stats["prefix_tokens_reused"] == 2 * 2 * 16

    def test_first_contact_co_arrivals_group(self):
        """Same-tick admissions sharing a prefix NOBODY has prefilled
        yet: the leader registers its pages at reservation time, so the
        followers match them in the same admission batch and ride one
        extend-prefill — two prefill dispatches total (leader full +
        follower tails), not three."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        # tails sized so both follower tails land in one padding bucket
        reqs = [dict(prompt=p, max_new_tokens=8)
                for p in self._prompts(tails=(5, 6, 7))]
        _, dense = run_engine(model, params, reqs, stagger=0,
                              kv_layout="dense", max_slots=4, max_len=64)
        eng = ServeEngine(EngineConfig(kv_layout="paged", max_slots=4,
                                       max_len=64), model, None, params)
        handles = [eng.submit(GenerationRequest(**r)) for r in reqs]
        eng.step()          # one tick admits all three
        # the followers decode against the leader's two prefix pages
        # (read-only shares), admitted in the same batch
        t = eng._tables
        assert (t[1, :2] == t[0, :2]).all() and (t[2, :2] == t[0, :2]).all()
        assert eng._shared[1, :2].all() and eng._shared[2, :2].all()
        assert not eng._shared[0, :2].any()      # leader owns them
        eng.drain()
        assert [h.tokens for h in handles] == dense
        assert eng.stats["prefix_hits"] == 2
        assert eng.stats["prefix_tokens_reused"] == 2 * 2 * 16
        assert eng.stats["prefill_calls"] == 2

    def test_first_contact_chained_registration_same_tick(self):
        """Same-tick trio with NESTED cold prefixes: A registers the
        system pages at reservation, B (deeper prompt) matches them and
        registers its extra page with start>0, and C — still in the same
        admission batch — matches the full 3-page chain A+B built
        moments earlier. Exercises register(start>0) at reservation
        time, not just the flat leader/follower split."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(3)
        sys_p = rng.randint(0, 257, 37)               # 2 full pages
        deep = np.concatenate([sys_p, rng.randint(0, 257, 16)])  # 3 full
        prompts = [np.concatenate([sys_p, rng.randint(0, 257, 5)]),
                   np.concatenate([deep, rng.randint(0, 257, 4)]),
                   np.concatenate([deep, rng.randint(0, 257, 6)])]
        reqs = [dict(prompt=p, max_new_tokens=6) for p in prompts]
        _, dense = run_engine(model, params, reqs, stagger=0,
                              kv_layout="dense", max_slots=4, max_len=80)
        eng = ServeEngine(EngineConfig(kv_layout="paged", max_slots=4,
                                       max_len=80), model, None, params)
        handles = [eng.submit(GenerationRequest(**r)) for r in reqs]
        eng.step()          # one tick admits all three
        # C rides the chain A+B registered this same tick: 3 shared pages
        sB, sC = handles[1].slot, handles[2].slot
        assert (eng._tables[sC][:3] == eng._tables[sB][:3]).all()
        assert eng._shared[sC][:3].all()
        assert eng._shared[sB][:2].all() and not eng._shared[sB][2]
        eng.drain()
        assert [h.tokens for h in handles] == dense
        assert eng.stats["prefix_hits"] == 2          # B and C
        # B reuses A's 2 pages; C reuses those plus B's page 2
        assert eng.stats["prefix_tokens_reused"] == (2 + 3) * 16

    def test_shared_pages_are_physically_shared(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        cfg = EngineConfig(max_slots=4, max_len=64, kv_layout="paged")
        eng = ServeEngine(cfg, model, None, params)
        p1, p2, _ = self._prompts()
        h1 = eng.submit(GenerationRequest(prompt=p1, max_new_tokens=12))
        eng.step()
        h2 = eng.submit(GenerationRequest(prompt=p2, max_new_tokens=12))
        eng.step()
        s1, s2 = h1.slot, h2.slot
        # both slots map logical pages 0-1 onto the SAME physical pages
        assert (eng._tables[s1][:2] == eng._tables[s2][:2]).all()
        assert eng._shared[s2][:2].all() and not eng._owned[s2][:2].any()
        for pid in eng._tables[s2][:2]:
            assert eng._pool.refcount(int(pid)) >= 3   # 2 slots + index
        eng.drain()

    def test_prefix_survives_retirement_for_future_requests(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        cfg = EngineConfig(max_slots=2, max_len=64, kv_layout="paged")
        eng = ServeEngine(cfg, model, None, params)
        p1, p2, _ = self._prompts()
        eng.submit(GenerationRequest(prompt=p1, max_new_tokens=4))
        eng.drain()                      # retired; index keeps the pages
        assert eng._pool.pages_used == 2
        h = eng.submit(GenerationRequest(prompt=p2, max_new_tokens=4))
        eng.drain()
        assert eng.stats["prefix_hits"] == 1 and h.done

    def test_warm_prefix_co_arrivals_share_one_prefill(self):
        """Two requests arriving in the SAME tick against an already-warm
        prefix land in one admission group: the gathered [1, S0, ...]
        prefix broadcasts across the group (regression: concat used to
        require matching batch) and tokens stay dense-equal."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        # tails 5/5 bucket together (one batch-2 extend group), 21 apart
        prompts = self._prompts(tails=(5, 5, 21))
        reqs = [dict(prompt=p, max_new_tokens=6) for p in prompts]
        _, dense = run_engine(model, params, reqs, stagger=0,
                              kv_layout="dense", max_slots=4, max_len=64)
        cfg = EngineConfig(max_slots=4, max_len=64, kv_layout="paged")
        eng = ServeEngine(cfg, model, None, params)
        warm = eng.submit(GenerationRequest(prompt=prompts[0],
                                            max_new_tokens=6))
        eng.drain()                       # registers the system pages
        prefills = eng.stats["prefill_calls"]
        hs = [eng.submit(GenerationRequest(prompt=p, max_new_tokens=6))
              for p in prompts]           # co-arrive in one tick
        eng.drain()
        assert [warm.tokens] + [h.tokens for h in hs] == \
            [dense[0]] + dense
        assert eng.stats["prefix_hits"] == 3
        # tails 5,5 bucket together -> 2 extend prefills, not 3
        assert eng.stats["prefill_calls"] == prefills + 2

    def test_pinned_prefix_pages_never_alias_own_pages(self):
        """Pool pressure while matching a warm prefix: eviction must not
        free the very pages the reservation just matched (they would be
        re-allocated as the slot's OWN pages and the prefill scatter
        would corrupt the prefix). The request waits instead, and tokens
        stay dense-equal."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(9)
        sys_prompt = rng.randint(0, 257, 33)     # 2 full shareable pages
        pa = np.concatenate([sys_prompt, rng.randint(0, 257, 5)])
        px = rng.randint(0, 257, 20)             # the busy neighbor
        pb = np.concatenate([sys_prompt, rng.randint(0, 257, 3)])
        reqs = [dict(prompt=pa, max_new_tokens=4),
                dict(prompt=px, max_new_tokens=10),
                dict(prompt=pb, max_new_tokens=4)]
        _, dense = run_engine(model, params, reqs, stagger=6,
                              kv_layout="dense", max_slots=2, max_len=64)
        # 4 usable pages: after A retires (2 registered) and X holds 2,
        # B's reservation matches 2 shared and must WAIT for an own page
        eng, paged = run_engine(model, params, reqs, stagger=6,
                                kv_layout="paged", max_slots=2,
                                max_len=64, kv_pages=5)
        assert paged == dense
        assert eng.stats["prefix_hits"] >= 1

    def test_mla_shared_prefix(self):
        model = reduced_model("minicpm3-4b")
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(4)
        V = model.cfg.vocab_size
        sys_prompt = rng.randint(0, V, 20)
        reqs = [dict(prompt=np.concatenate([sys_prompt,
                                            rng.randint(0, V, t)]),
                     max_new_tokens=5) for t in (4, 7)]
        _, dense = run_engine(model, params, reqs, stagger=2,
                              kv_layout="dense", max_slots=2, max_len=48)
        eng, paged = run_engine(model, params, reqs, stagger=2,
                                kv_layout="paged", max_slots=2, max_len=48)
        assert paged == dense and eng.stats["prefix_hits"] == 1

    def test_param_swap_flushes_stale_prefix_pages(self):
        """Hot-reloaded weights invalidate every registered prefix page
        (their K/V was computed under the old params): post-swap requests
        re-prefill from scratch and match the dense engine on the NEW
        weights — no silent version mixing."""
        model = tiny_model()
        p_old = model.init(jax.random.key(0))
        p_new = model.init(jax.random.key(1))
        prompts = self._prompts()
        eng = ServeEngine(EngineConfig(max_slots=2, max_len=64,
                                       kv_layout="paged"),
                          model, None, p_old)
        eng.submit(GenerationRequest(prompt=prompts[0], max_new_tokens=4))
        eng.drain()                        # warm index under OLD weights
        assert len(eng._prefix) == 2
        eng.swap_params(p_new)
        assert len(eng._prefix) == 0       # flushed
        h = eng.submit(GenerationRequest(prompt=prompts[1],
                                         max_new_tokens=6))
        eng.drain()
        assert eng.stats["prefix_hits"] == 0
        _, dense = run_engine(model, p_new,
                              [dict(prompt=prompts[1], max_new_tokens=6)],
                              kv_layout="dense", max_slots=2, max_len=64)
        assert h.tokens == dense[0]

    def test_swa_never_shares(self):
        model = reduced_model("mixtral-8x22b")
        params = model.init(jax.random.key(0))
        eng = ServeEngine(EngineConfig(max_slots=2, max_len=48,
                                       kv_layout="paged"),
                          model, None, params)
        assert eng._prefix is None     # rolling pages churn: sharing off


# --------------------------------------------------------- pool pressure
class TestPoolPressure:
    def test_preemption_recompute_is_bitwise(self):
        """A starved arena preempts the youngest request; re-admission
        re-prefills prompt+generated — the final streams are identical
        to an unconstrained run."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(5)
        reqs = [dict(prompt=rng.randint(0, 257, n), max_new_tokens=20)
                for n in (20, 25, 18)]
        kw = dict(max_slots=3, max_len=48, prefix_sharing=False)
        _, full = run_engine(model, params, reqs, kv_layout="paged", **kw)
        eng, tight = run_engine(model, params, reqs, kv_layout="paged",
                                kv_pages=6, **kw)
        assert tight == full
        assert eng.stats["preemptions"] >= 1
        assert eng.throughput()["completed"] == 3

    def test_cold_prefix_pages_evicted_under_pressure(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(6)
        cfg = EngineConfig(max_slots=1, max_len=48, kv_layout="paged",
                           kv_pages=4)       # 3 pages + trash: exactly 1 slot
        eng = ServeEngine(cfg, model, None, params)
        eng.submit(GenerationRequest(prompt=rng.randint(0, 257, 20),
                                     max_new_tokens=4))
        eng.drain()
        assert len(eng._prefix) == 1         # one warm prefix page
        h = eng.submit(GenerationRequest(prompt=rng.randint(0, 257, 30),
                                         max_new_tokens=4))
        eng.drain()                          # needs all 3 pages: evict
        assert h.done and len(eng._prefix) <= 1

    def test_forced_cow_preserves_tokens(self):
        """An extra reference on a page a running slot is about to write
        (what rolling-over-a-shared-page would produce) triggers COW; the
        slot copies the page and decodes on, bitwise-unchanged."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(7)
        prompt = rng.randint(0, 257, 20)
        _, ref = run_engine(model, params,
                            [dict(prompt=prompt.copy(),
                                  max_new_tokens=20)],
                            kv_layout="dense", max_len=48)
        eng = ServeEngine(EngineConfig(max_slots=2, max_len=48,
                                       kv_layout="paged"),
                          model, None, params)
        h = eng.submit(GenerationRequest(prompt=prompt.copy(),
                                         max_new_tokens=20))
        eng.step()
        slot = h.slot
        lp = int(eng._host_pos[slot]) // eng._page_size
        pid = int(eng._tables[slot, lp])
        eng._pool.ref([pid])                 # simulate external sharing
        eng._shared[slot, lp] = True
        eng._owned[slot, lp] = False
        eng.drain()
        eng._pool.release([pid])
        assert eng.stats["cow_copies"] == 1
        assert h.tokens == ref[0]


# ------------------------------------------------------------ allocator
class TestPagePool:
    def test_alloc_free_refcount_roundtrip(self):
        pool = PagePool(8, 16)
        assert pool.pages_free == 7          # page 0 is trash
        a = pool.alloc(3)
        assert len(a) == 3 and 0 not in a and pool.pages_used == 3
        pool.ref(a[:1])
        pool.release(a)                      # a[0] survives (refcount 1)
        assert pool.pages_used == 1 and pool.refcount(a[0]) == 1
        pool.release(a[:1])
        assert pool.pages_used == 0 and pool.pages_free == 7

    def test_alloc_exhaustion_returns_none(self):
        pool = PagePool(4, 8)
        assert pool.alloc(4) is None         # only 3 allocatable
        got = pool.alloc(3)
        assert got is not None and pool.alloc(1) is None

    def test_cow_moves_reference(self):
        pool = PagePool(6, 8)
        (p,) = pool.alloc(1)
        pool.ref([p])                        # shared: refcount 2
        q = pool.cow(p)
        assert q is not None and q != p
        assert pool.refcount(p) == 1 and pool.refcount(q) == 1

    def test_fragmentation_churn_never_leaks(self):
        """Random admit/retire cycles: every page the bookkeeping says is
        used is referenced, and a full drain returns the pool to empty."""
        rng = np.random.RandomState(8)
        pool = PagePool(17, 4)
        held = []
        for _ in range(200):
            if held and rng.rand() < 0.45:
                pool.release(held.pop(rng.randint(len(held))))
            else:
                n = int(rng.randint(1, 4))
                got = pool.alloc(n)
                if got is None:
                    continue
                held.append(got)
            assert pool.pages_used == sum(len(h) for h in held)
            assert pool.pages_used + pool.pages_free == pool.num_pages - 1
        for h in held:
            pool.release(h)
        assert pool.pages_used == 0

    def test_kv_byte_accounting_matches_layouts(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        cfg = EngineConfig(max_slots=2, max_len=48, kv_layout="paged")
        eng = ServeEngine(cfg, model, None, params)
        # full provisioning: arena capacity == the dense footprint
        dense = ServeEngine(EngineConfig(max_slots=2, max_len=48,
                                         kv_layout="dense"),
                            model, None, params)
        assert eng._kv_capacity_bytes == dense._kv_capacity_bytes
        assert (paged_kv_page_bytes(eng.cache) * (eng._num_pages - 1)
                == dense_kv_bytes(dense.cache))


# ----------------------------------------------------------- prefix index
class TestPrefixIndex:
    def test_chain_match_register_and_divergence(self):
        idx = PrefixIndex(4)
        a = np.arange(20)                       # pages: [0:4],[4:8],[8:12],[12:16]
        assert idx.max_shareable(a) == 4
        assert idx.match(a) == []
        newly = idx.register(a, [7, 8, 9, 10])
        assert newly == [7, 8, 9, 10]
        b = np.concatenate([a[:8], 99 + np.arange(8)])   # diverges at page 2
        assert idx.match(b) == [7, 8]
        assert idx.register(b, [11], start=2) == [11]
        assert idx.match(b) == [7, 8, 11]
        assert idx.match(a) == [7, 8, 9, 10]

    def test_last_token_never_shared(self):
        idx = PrefixIndex(4)
        p = np.arange(8)                     # 2 full pages, but max 1 shared
        assert idx.max_shareable(p) == 1
        idx.register(p, [3])
        assert idx.match(np.arange(8)) == [3]

    def test_lru_evicts_chain_leaves_first(self):
        idx = PrefixIndex(4)
        idx.register(np.arange(13), [5, 6, 7])
        assert idx.evict_lru() == 7          # deepest page first
        assert idx.match(np.arange(13)) == [5, 6]
        idx.forget(6)
        assert idx.match(np.arange(13)) == [5]


# ---------------------------------------------------------------- config
class TestPagedConfig:
    def test_roundtrip_and_cli(self):
        cfg = EngineConfig(arch="qwen3-32b", kv_layout="paged",
                           page_size=32, kv_pages=64, prefix_sharing=False)
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg
        cli = EngineConfig.from_cli(
            ["--arch", "hymba-1p5b", "--kv-layout", "dense",
             "--page-size", "8", "--kv-pages", "40",
             "--no-prefix-sharing"])
        assert (cli.kv_layout, cli.page_size, cli.kv_pages,
                cli.prefix_sharing) == ("dense", 8, 40, False)
        assert EngineConfig.from_dict(cli.to_dict()) == cli

    def test_max_len_default_composes_with_page_size(self):
        # max_len=0 => seq_len, rounded UP to a page multiple
        cfg = EngineConfig(seq_len=100, page_size=16)
        assert cfg.serve_max_len() == 112
        assert EngineConfig(max_len=48, page_size=16).serve_max_len() == 48
        assert EngineConfig(max_len=50, page_size=16).serve_max_len() == 64
        assert EngineConfig(max_len=50,
                            kv_layout="dense").serve_max_len() == 50

    def test_validation_errors_are_clear(self):
        with pytest.raises(ValueError, match="page_size"):
            EngineConfig(page_size=0).validate()
        with pytest.raises(ValueError, match="kv_layout"):
            EngineConfig(kv_layout="mmap").validate()
        with pytest.raises(ValueError, match="kv_pages"):
            EngineConfig(kv_pages=-1).validate()
        with pytest.raises(ValueError, match="trash page"):
            EngineConfig(kv_pages=1).validate()
        # the one-full-slot minimum is model-aware (sliding windows cap
        # the paged capacity below max_len), so it lives in the engine
        EngineConfig(max_len=4096, page_size=16, kv_pages=16).validate()
        model = tiny_model()
        with pytest.raises(ValueError, match="cannot hold even one"):
            ServeEngine(EngineConfig(max_slots=2, max_len=64,
                                     kv_pages=3),
                        model, None, model.init(jax.random.key(0)))
        # dense layout never trips the paged checks
        EngineConfig(kv_layout="dense", page_size=0).validate()

    def test_engine_rounds_max_len_up(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        eng = ServeEngine(EngineConfig(max_slots=2, max_len=40,
                                       kv_layout="paged"),
                          model, None, params)
        assert eng.max_len == 48 and eng._pages_per_slot == 3


# ---------------------------------------------------------------- kernel
class TestPagedDecodeKernel:
    def _ref(self, q, kp, vp, pt, pos, rolling):
        import math
        B, H, Dh = q.shape
        _, ps, KV, _ = kp.shape
        P = pt.shape[1]
        cap = P * ps
        G = H // KV
        kf = kp[pt].reshape(B, cap, KV, Dh)
        vf = vp[pt].reshape(B, cap, KV, Dh)
        idx = np.arange(cap)
        posb = pos[:, None]
        slot_pos = ((posb - ((posb - idx[None, :]) % cap)) if rolling
                    else np.broadcast_to(idx[None], (B, cap)))
        valid = (slot_pos >= 0) & (slot_pos <= posb)
        qg = q.reshape(B, KV, G, Dh)
        s = np.einsum("bkgd,bskd->bkgs", qg, kf) / math.sqrt(Dh)
        s = np.where(valid[:, None, None, :], s, -1e30)
        p = np.asarray(jax.nn.softmax(jnp.asarray(s), axis=-1))
        return np.einsum("bkgs,bskd->bkgd", p, vf).reshape(B, H, Dh)

    @pytest.mark.parametrize("rolling", [False, True])
    def test_kernel_matches_ref_gather(self, rolling):
        from repro.kernels.flash_attention import paged_decode_attention
        rng = np.random.RandomState(0)
        B, H, KV, Dh, ps, P, NP = 3, 8, 2, 16, 4, 3, 12
        q = rng.randn(B, H, Dh).astype(np.float32)
        kp = rng.randn(NP, ps, KV, Dh).astype(np.float32)
        vp = rng.randn(NP, ps, KV, Dh).astype(np.float32)
        pt = np.stack([rng.permutation(np.arange(1, NP))[:P]
                       for _ in range(B)]).astype(np.int32)
        pos = np.asarray([0, 7, 25], np.int32)   # fresh, mid, wrapped
        out = paged_decode_attention(
            jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(pt), jnp.asarray(pos), rolling=rolling,
            interpret=True)
        ref = self._ref(q, kp, vp, pt, pos, rolling)
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)

    def test_kernel_mqa_single_group(self):
        from repro.kernels.flash_attention import paged_decode_attention
        rng = np.random.RandomState(1)
        B, H, KV, Dh, ps, P, NP = 2, 4, 4, 8, 4, 2, 9
        q = rng.randn(B, H, Dh).astype(np.float32)
        kp = rng.randn(NP, ps, KV, Dh).astype(np.float32)
        vp = rng.randn(NP, ps, KV, Dh).astype(np.float32)
        pt = np.asarray([[1, 2], [3, 4]], np.int32)
        pos = np.asarray([3, 6], np.int32)
        out = paged_decode_attention(
            jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(pt), jnp.asarray(pos), interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), self._ref(q, kp, vp, pt, pos, False),
            atol=1e-5)
