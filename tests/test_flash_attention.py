"""Flash-attention Pallas kernel: shape/dtype/mask sweeps vs the chunked
oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import _chunked_attention


def data(B, T, H, KV, Dh, dtype, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32).astype(dtype)
    return mk(B, T, H, Dh), mk(B, T, KV, Dh), mk(B, T, KV, Dh)


@pytest.mark.parametrize("B,T,H,KV,Dh", [
    (2, 256, 4, 2, 32), (1, 128, 8, 8, 16), (1, 512, 4, 1, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_oracle(B, T, H, KV, Dh, dtype):
    q, k, v = data(B, T, H, KV, Dh, dtype)
    pos = jnp.arange(T, dtype=jnp.float32)
    want = _chunked_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), pos, pos, causal=True,
                              window=0, chunk=64)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("window", [32, 96])
def test_flash_sliding_window(window):
    q, k, v = data(1, 256, 4, 2, 32, jnp.float32)
    pos = jnp.arange(256, dtype=jnp.float32)
    want = _chunked_attention(q, k, v, pos, pos, causal=True,
                              window=window, chunk=64)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_noncausal():
    q, k, v = data(2, 128, 4, 4, 32, jnp.float32)
    pos = jnp.arange(128, dtype=jnp.float32)
    want = _chunked_attention(q, k, v, pos, pos, causal=False, window=0,
                              chunk=64)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x22b",
                                  "hymba-1.5b"])
def test_head_padding_is_exact(arch):
    """pad_heads_for_tp + convert_gqa_params: the padded parameterization
    must produce identical attention-block outputs."""
    from repro.configs.base import get_reduced, pad_heads_for_tp
    from repro.models.attention import (gqa_init, gqa_forward,
                                        convert_gqa_params)
    cfg = get_reduced(arch)
    cfg_pad = pad_heads_for_tp(cfg, 16)
    assert cfg_pad.n_heads % 16 == 0 and cfg_pad.n_kv_heads % 16 == 0
    p = gqa_init(jax.random.key(0), cfg, jnp.float32)
    p_pad = convert_gqa_params(p, cfg, cfg_pad)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model)) * 0.3
    pos = jnp.arange(32, dtype=jnp.float32)
    out = gqa_forward(p, cfg, x, pos, jnp.float32, chunk=16)
    out_pad = gqa_forward(p_pad, cfg_pad, x, pos, jnp.float32, chunk=16)
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out),
                               rtol=1e-5, atol=1e-5)
