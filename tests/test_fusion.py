"""Tensor fusion layout tests (paper §4.4.3) incl. hypothesis round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fusion


def tree_from(sizes):
    rng = np.random.default_rng(sum(sizes) + len(sizes))
    return {f"l{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
            for i, s in enumerate(sizes)}


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 300), min_size=1, max_size=8),
       st.sampled_from([1, 4, 16]), st.sampled_from([1, 8, 64]))
def test_pack_unpack_roundtrip(sizes, align, leaf_align):
    tree = tree_from(sizes)
    layout = fusion.make_layout(tree, align=align, leaf_align=leaf_align)
    buf = fusion.pack(tree, layout)
    assert buf.shape[0] == layout.padded_len
    assert layout.padded_len % (align * leaf_align) == 0
    out = fusion.unpack(buf, layout)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 200), min_size=1, max_size=6),
       st.sampled_from([8, 32]))
def test_leaf_alignment_contract(sizes, leaf_align):
    """Every leaf starts at a multiple of leaf_align (the Pallas block
    contract) and segment ids agree with offsets."""
    tree = tree_from(sizes)
    layout = fusion.make_layout(tree, leaf_align=leaf_align)
    seg = layout.segment_ids()
    for i, (off, sz) in enumerate(zip(layout.offsets, layout.sizes)):
        assert off % leaf_align == 0
        assert (seg[off:off + sz] == i).all()
    # padding/gaps are the dummy segment
    mask = np.ones(layout.padded_len, bool)
    for off, sz in zip(layout.offsets, layout.sizes):
        mask[off:off + sz] = False
    assert (seg[mask] == layout.num_segments).all()


def test_multidim_leaves():
    tree = {"a": jnp.arange(24.0).reshape(2, 3, 4),
            "b": jnp.arange(5.0)}
    layout = fusion.make_layout(tree, align=4)
    out = fusion.unpack(fusion.pack(tree, layout), layout)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["a"].shape == (2, 3, 4)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 10_000), min_size=1, max_size=20),
       st.integers(1, 64))
def test_bucketize_never_splits_layers(sizes, kb):
    tree = {f"l{i}": jax.ShapeDtypeStruct((s,), jnp.float32)
            for i, s in enumerate(sizes)}
    layout = fusion.make_layout(tree)
    buckets = fusion.bucketize(layout, bucket_bytes=kb * 1024)
    # contiguous cover, no overlap
    assert buckets[0][0] == 0 and buckets[-1][1] == len(sizes)
    for (s1, e1), (s2, e2) in zip(buckets, buckets[1:]):
        assert e1 == s2
