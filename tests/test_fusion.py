"""Tensor fusion layout tests (paper §4.4.3): deterministic bucketize /
round-trip coverage that always runs, plus hypothesis property tests when
hypothesis is installed (the container may not ship it)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests skip; deterministic ones run
    given = settings = st = None

from repro.core import fusion


def tree_from(sizes):
    rng = np.random.default_rng(sum(sizes) + len(sizes))
    return {f"l{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
            for i, s in enumerate(sizes)}


# ------------------------------------------------- deterministic: round-trip

ALIGN_CASES = [
    ([1], 1, 1, "float32"),
    ([3, 5, 7], 4, 8, "float32"),
    ([128, 1, 64], 16, 64, "float32"),
    ([100, 200, 300, 17], 1, 8, "bfloat16"),
    ([8192], 2, 64, "bfloat16"),
]


@pytest.mark.parametrize("sizes,align,leaf_align,dtype", ALIGN_CASES)
def test_pack_unpack_roundtrip_under_alignment(sizes, align, leaf_align,
                                               dtype):
    """Round-trip with alignment gaps + tail padding + a dtype that
    upcasts through the fused buffer: values and dtypes must survive."""
    rng = np.random.default_rng(len(sizes))
    tree = {f"l{i}": jnp.asarray(rng.standard_normal(s), jnp.dtype(dtype))
            for i, s in enumerate(sizes)}
    layout = fusion.make_layout(tree, align=align, leaf_align=leaf_align)
    buf = fusion.pack(tree, layout, dtype=jnp.float32)
    assert buf.dtype == jnp.float32
    assert buf.shape[0] == layout.padded_len
    assert layout.padded_len % (align * leaf_align) == 0
    out = fusion.unpack(buf, layout)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(out[k])).astype(np.float32),
            np.asarray(jax.device_get(tree[k])).astype(np.float32))
    # the gaps the round-trip skipped really are zero (wire payload)
    seg = layout.segment_ids()
    gaps = np.asarray(buf)[seg == layout.num_segments]
    assert (gaps == 0).all()


def test_multidim_leaves():
    tree = {"a": jnp.arange(24.0).reshape(2, 3, 4),
            "b": jnp.arange(5.0)}
    layout = fusion.make_layout(tree, align=4)
    out = fusion.unpack(fusion.pack(tree, layout), layout)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["a"].shape == (2, 3, 4)


# -------------------------------------------------- deterministic: bucketize

def test_bucketize_oversized_single_leaf():
    """A leaf bigger than the bucket budget gets a bucket of its own —
    never split, never merged with its neighbours."""
    tree = {"small0": jax.ShapeDtypeStruct((4,), jnp.float32),
            "huge": jax.ShapeDtypeStruct((100_000,), jnp.float32),
            "small1": jax.ShapeDtypeStruct((4,), jnp.float32)}
    layout = fusion.make_layout(tree)
    buckets = fusion.bucketize(layout, bucket_bytes=1024)
    hi = list(layout.sizes).index(100_000)
    owner = [b for b in buckets if b[0] <= hi < b[1]]
    assert len(owner) == 1 and owner[0][1] - owner[0][0] == 1, buckets


def test_bucketize_single_oversized_only_leaf():
    tree = {"huge": jax.ShapeDtypeStruct((100_000,), jnp.float32)}
    layout = fusion.make_layout(tree)
    assert fusion.bucketize(layout, bucket_bytes=16) == [(0, 1)]


def test_bucketize_exact_boundary_fill():
    """Leaves that exactly fill the budget must not spill the last one
    into the next bucket (> vs >= off-by-one guard)."""
    # four 64-element fp32 leaves = 256 B each; budget = exactly 2 leaves
    tree = {f"l{i}": jax.ShapeDtypeStruct((64,), jnp.float32)
            for i in range(4)}
    layout = fusion.make_layout(tree)
    buckets = fusion.bucketize(layout, bucket_bytes=2 * 64 * 4)
    assert buckets == [(0, 2), (2, 4)], buckets


@pytest.mark.parametrize("sizes,budget_b", [
    ([4, 4, 4, 4], 16),
    ([1000, 1, 1, 1000, 1], 512),
    ([64] * 7, 64 * 4),
    ([3000, 3000], 1024),
])
def test_bucketize_budget_respected_unless_oversized(sizes, budget_b):
    """Contiguous cover; every bucket fits the budget except single-leaf
    buckets whose one leaf is itself oversized."""
    tree = {f"l{i}": jax.ShapeDtypeStruct((s,), jnp.float32)
            for i, s in enumerate(sizes)}
    layout = fusion.make_layout(tree)
    buckets = fusion.bucketize(layout, bucket_bytes=budget_b)
    assert buckets[0][0] == 0 and buckets[-1][1] == len(sizes)
    for (s1, e1), (s2, e2) in zip(buckets, buckets[1:]):
        assert e1 == s2
    for s, e in buckets:
        nbytes = sum(layout.sizes[s:e]) * 4
        assert nbytes <= budget_b or e - s == 1


# ------------------------------------------------------ hypothesis variants

if st is not None:

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(1, 300), min_size=1, max_size=8),
           st.sampled_from([1, 4, 16]), st.sampled_from([1, 8, 64]))
    def test_pack_unpack_roundtrip(sizes, align, leaf_align):
        tree = tree_from(sizes)
        layout = fusion.make_layout(tree, align=align, leaf_align=leaf_align)
        buf = fusion.pack(tree, layout)
        assert buf.shape[0] == layout.padded_len
        assert layout.padded_len % (align * leaf_align) == 0
        out = fusion.unpack(buf, layout)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(tree[k]))

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(1, 200), min_size=1, max_size=6),
           st.sampled_from([8, 32]))
    def test_leaf_alignment_contract(sizes, leaf_align):
        """Every leaf starts at a multiple of leaf_align (the Pallas block
        contract) and segment ids agree with offsets."""
        tree = tree_from(sizes)
        layout = fusion.make_layout(tree, leaf_align=leaf_align)
        seg = layout.segment_ids()
        for i, (off, sz) in enumerate(zip(layout.offsets, layout.sizes)):
            assert off % leaf_align == 0
            assert (seg[off:off + sz] == i).all()
        # padding/gaps are the dummy segment
        mask = np.ones(layout.padded_len, bool)
        for off, sz in zip(layout.offsets, layout.sizes):
            mask[off:off + sz] = False
        assert (seg[mask] == layout.num_segments).all()

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(1, 10_000), min_size=1, max_size=20),
           st.integers(1, 64))
    def test_bucketize_never_splits_layers(sizes, kb):
        tree = {f"l{i}": jax.ShapeDtypeStruct((s,), jnp.float32)
                for i, s in enumerate(sizes)}
        layout = fusion.make_layout(tree)
        buckets = fusion.bucketize(layout, bucket_bytes=kb * 1024)
        # contiguous cover, no overlap
        assert buckets[0][0] == 0 and buckets[-1][1] == len(sizes)
        for (s1, e1), (s2, e2) in zip(buckets, buckets[1:]):
            assert e1 == s2
