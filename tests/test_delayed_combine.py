"""Delayed-combine (combine_delay=1) tests: the overlapped execution
mode and everything that rode along with it — the combine_delay=0
no-op contract, the split-stream executor's bitwise equality to the
single-program step, checkpoint/elastic restart of the in-flight
pending carry, the span==dp fused-fallback warning + combine_path
surfacing, real aux metrics out of the local-step scan, and the
benchmark history topology fields."""
import json
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.engine import EngineConfig


# ------------------------------------------------- combine_delay=0 contract

def test_delay0_bitwise_noop_across_spans_and_points():
    """combine_delay=0 must leave the synchronous paths exactly as they
    were: no pending carry, no delayed machinery, and bitwise-reproducible
    states across independently built sessions, for every span and both
    combine points."""
    run_in_subprocess(r"""
import jax, numpy as np
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("tiny", "dense", 2, 32, 2, 1, 64, 97, head_dim=16)
model = build_model(mcfg, attn_chunk=16)
mesh = make_mesh_compat((8, 1), ("data", "model"))
for span in (2, 4, 8):
    for point in ("pre", "post"):
        cfg = EngineConfig(combine="adasum", backend="gspmd_tree",
                           span=span, combine_point=point,
                           optimizer="adam", seq_len=16, global_batch=16,
                           data_seed=3, combine_delay=0)
        states = []
        for _ in range(2):
            sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                            callbacks=[])
            assert "pending" not in sess.state, (span, point)
            assert sess.runtime.correction_fn is None
            assert sess.runtime.local_fn is None
            for s in range(3):
                sess.step(sess.batch(s))
            states.append(jax.device_get(sess.state["params"]))
            sess.close()
        a, b = states
        for (p, x), (_, y) in zip(
                jax.tree_util.tree_flatten_with_path(a)[0],
                jax.tree_util.tree_flatten_with_path(b)[0]):
            assert (np.asarray(x) == np.asarray(y)).all(), (span, point, p)
print("OK")
""", devices=8, timeout=900)


# ----------------------------------------- delayed execution paths, bitwise

def test_delayed_paths_bitwise_and_cold_start_zero():
    """The three executions of a delayed round — single-program
    `delayed_local_step`, the stream's overlapped step, the stream's
    inline serial step — must produce bitwise-identical params AND
    pending carry; the step-0 correction of the zero carry is exactly
    zero (no cold-start branch in the trace)."""
    run_in_subprocess(r"""
import jax, numpy as np
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat
from repro.runtime import DelayedCombineStream

mcfg = ModelConfig("tiny", "dense", 2, 32, 2, 1, 64, 97, head_dim=16)
model = build_model(mcfg, attn_chunk=16)
mesh = make_mesh_compat((8, 1), ("data", "model"))
cfg = EngineConfig(combine="adasum", backend="gspmd_tree", span=4,
                   optimizer="adam", seq_len=16, global_batch=16,
                   data_seed=3, combine_delay=1)

def flat(t):
    return jax.tree_util.tree_flatten_with_path(jax.device_get(t))[0]

sess = TrainSession.from_config(cfg, model=model, mesh=mesh, callbacks=[])
for p, leaf in flat(sess.runtime.correction_fn(sess.state["pending"])):
    assert (np.asarray(leaf) == 0).all(), p
sess.close()

finals = []
for mode in ("single", "stream", "serial"):
    s = TrainSession.from_config(cfg, model=model, mesh=mesh, callbacks=[])
    if mode == "stream":
        s.use_delayed_stream(comm_delay=0.002)
        for i in range(4):
            m = s.step(s.batch(i))
        assert "compute_s" in m and "combine_wait_s" in m, m
    elif mode == "serial":
        stream = DelayedCombineStream(s.runtime)
        for i in range(4):
            s.state, _ = stream.serial_step(s.state, s.batch(i))
        stream.close()
    else:
        for i in range(4):
            s.step(s.batch(i))
    finals.append((flat(s.state["params"]), flat(s.state["pending"])))
    s.close()
(ref_p, ref_d) = finals[0]
for name, (ps, ds) in zip(("stream", "serial"), finals[1:]):
    for (path, x), (_, y) in zip(ref_p, ps):
        assert (np.asarray(x) == np.asarray(y)).all(), (name, path)
    for (path, x), (_, y) in zip(ref_d, ds):
        assert (np.asarray(x) == np.asarray(y)).all(), (name, path)
print("OK")
""", devices=8, timeout=900)


def test_delayed_checkpoint_restart_mid_round_bitwise(tmp_path):
    """Elastic-restart contract for the in-flight exchange: 6 straight
    delayed rounds == 3 rounds + checkpoint (a pending delta is parked
    mid-pipeline) + fresh-process restore + 3 more rounds, bitwise on
    params and the pending carry — the in-flight delta is replayed,
    never dropped or double-applied."""
    run_in_subprocess(rf"""
import jax, numpy as np
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("tiny", "dense", 2, 32, 2, 1, 64, 97, head_dim=16)
kw = dict(combine="adasum", backend="gspmd_tree", span=4,
          optimizer="adam", seq_len=16, global_batch=16, data_seed=3,
          combine_delay=1, log_every=1)

def build(ck=""):
    model = build_model(mcfg, attn_chunk=16)
    mesh = make_mesh_compat((8, 1), ("data", "model"))
    extra = dict(ckpt_dir=ck, ckpt_every=3) if ck else {{}}
    cfg = EngineConfig(**kw, **extra)
    # default callbacks: CheckpointCallback does the ckpt_every saves
    return TrainSession.from_config(cfg, model=model, mesh=mesh)

a = build()
a.fit(6)

b1 = build(r"{tmp_path}/ck")
b1.fit(3)
assert b1.checkpoint.latest_step() == 3
b1.close()
b2 = build(r"{tmp_path}/ck")
b2.fit(6)
assert int(jax.device_get(b2.state["step"])) == 6

def flat(t):
    return jax.tree_util.tree_flatten_with_path(jax.device_get(t))[0]

for part in ("params", "pending"):
    for (p, x), (_, y) in zip(flat(a.state[part]), flat(b2.state[part])):
        assert (np.asarray(x) == np.asarray(y)).all(), (part, p)
print("OK")
""", devices=8, timeout=900)


# ------------------------------------------------ fallback warning + metadata

def test_span_eq_dp_fused_fallback_warns_and_tags_combine_path():
    """span==dp with the fused gspmd_tree path requested is the RVH
    regime: the build must warn ONCE (EngineWarning, not silence) and
    surface 'gspmd-reference' as the active combine path in the run
    metadata; span<dp stays 'gspmd-fused' with no warning."""
    run_in_subprocess(r"""
import warnings
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.engine.build import EngineWarning
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("tiny", "dense", 2, 32, 2, 1, 64, 97, head_dim=16)
model = build_model(mcfg, attn_chunk=16)
mesh = make_mesh_compat((8, 1), ("data", "model"))
with warnings.catch_warnings(record=True) as rec:
    warnings.simplefilter("always")
    sess = TrainSession.from_config(
        EngineConfig(combine="adasum", backend="gspmd_tree", span=8,
                     seq_len=16, global_batch=16),
        model=model, mesh=mesh, callbacks=[])
hits = [w for w in rec if issubclass(w.category, EngineWarning)
        and "span == dp" in str(w.message)]
assert len(hits) == 1, [str(w.message) for w in rec]
md = sess.run_metadata()
assert md["combine_path"] == "gspmd-reference", md
assert md["devices"] == 8 and md["mesh"] == {"data": 8, "model": 1}, md
sess.close()

with warnings.catch_warnings(record=True) as rec2:
    warnings.simplefilter("always")
    s2 = TrainSession.from_config(
        EngineConfig(combine="adasum", backend="gspmd_tree", span=4,
                     seq_len=16, global_batch=16),
        model=model, mesh=mesh, callbacks=[])
assert not [w for w in rec2 if issubclass(w.category, EngineWarning)], \
    [str(w.message) for w in rec2]
assert s2.run_metadata()["combine_path"] == "gspmd-fused"
s2.close()
print("OK")
""", devices=8, timeout=600)


def test_run_metadata_keys_on_tiny_session():
    from repro.configs.base import ModelConfig
    from repro.engine import TrainSession
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    mcfg = ModelConfig("tiny", "dense", 2, 32, 2, 1, 64, 97, head_dim=16)
    sess = TrainSession.from_config(
        EngineConfig(combine="adasum", seq_len=16, global_batch=4),
        model=build_model(mcfg, attn_chunk=16),
        mesh=make_local_mesh(1, 1), callbacks=[])
    md = sess.run_metadata()
    for key in ("arch", "combine", "backend", "combine_path", "span",
                "dp", "local_steps", "combine_delay", "devices", "mesh"):
        assert key in md, (key, md)
    assert md["combine_delay"] == 0
    assert md["devices"] == 1 and md["mesh"] == {"data": 1, "model": 1}
    assert md["combine_path"], md
    sess.close()


# -------------------------------------------------- local-step aux metrics

def test_local_sgd_step_reports_real_aux():
    """The local-step scan used to throw the aux loss away and log a
    constant zero; on a MoE arch the reported aux must be the real
    (positive) load-balance mean, same metric keys as sync_step."""
    from repro.configs.base import get_reduced
    from repro.engine import TrainSession
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    mcfg = get_reduced("moonshot-v1-16b-a3b")
    sess = TrainSession.from_config(
        EngineConfig(combine="adasum", optimizer="momentum",
                     local_steps=2, seq_len=16, global_batch=4,
                     log_every=1),
        model=build_model(mcfg, attn_chunk=16),
        mesh=make_local_mesh(1, 1), callbacks=[])
    m = sess.step(sess.batch(0))
    assert {"loss", "aux", "grad_lanes"} <= set(m), m
    assert np.isfinite(m["loss"])
    assert float(m["aux"]) > 0, (
        f"local-step aux must be the real MoE aux mean, got {m['aux']}")
    sess.close()


# ---------------------------------------------------- config + CLI plumbing

def test_combine_delay_config_validation_and_cli_roundtrip():
    with pytest.raises(ValueError, match="combine_delay must be 0"):
        EngineConfig(combine_delay=2, global_batch=16).validate()
    with pytest.raises(ValueError, match="mutually exclusive"):
        EngineConfig(combine_delay=1, accum_steps=2,
                     global_batch=16).validate()
    EngineConfig(combine_delay=1, global_batch=16).validate()

    cfg = EngineConfig.from_cli(["--arch", "gemma-7b", "--combine-delay",
                                 "1", "--batch", "16"])
    assert cfg.combine_delay == 1
    assert EngineConfig.from_dict(cfg.to_dict()) == cfg
    # and the default stays synchronous
    assert EngineConfig.from_cli(
        ["--arch", "gemma-7b", "--batch", "16"]).combine_delay == 0


# -------------------------------------------------- benchmark history fields

def test_append_history_records_device_topology(tmp_path, monkeypatch):
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    import benchmarks.common as C

    monkeypatch.setattr(C, "HISTORY", tmp_path / "h.jsonl")
    C.append_history("t1", {"x": 1}, devices=8,
                     mesh={"data": 8, "model": 1})
    C.append_history("t2", {"y": 2}, mesh=None)
    rows = [json.loads(ln) for ln in
            (tmp_path / "h.jsonl").read_text().splitlines()]
    assert rows[0]["devices"] == 8
    assert rows[0]["mesh"] == {"data": 8, "model": 1}
    assert rows[1]["mesh"] is None
    assert rows[1]["devices"] == jax.device_count()
    assert all("bench" in r and "ts" in r and "result" in r for r in rows)
