"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU). Hypothesis-based tests skip individually when
hypothesis isn't installed; the deterministic sweeps always run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.kernels import ops, ref
from repro.kernels.adasum_dots import block_dots
from repro.kernels.adasum_combine import block_combine
from repro.kernels.backend import interpret_default, resolve_interpret

BLOCKS = [1024, 2048, 8192]
DTYPES = [jnp.float32, jnp.bfloat16]


def data(n, seed, dtype):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal(n), jnp.float32).astype(dtype),
            jnp.asarray(rng.standard_normal(n), jnp.float32).astype(dtype))


@pytest.mark.parametrize("block", BLOCKS)
@pytest.mark.parametrize("nblk", [1, 3, 7])
@pytest.mark.parametrize("dtype", DTYPES)
def test_block_dots_sweep(block, nblk, dtype):
    a, b = data(block * nblk, block + nblk, dtype)
    got = block_dots(a, b, block_elems=block, interpret=True)
    want = ref.block_dots_ref(a, b, block)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 100)


@pytest.mark.parametrize("block", BLOCKS)
@pytest.mark.parametrize("nblk", [1, 4])
@pytest.mark.parametrize("dtype", DTYPES)
def test_block_combine_sweep(block, nblk, dtype):
    a, b = data(block * nblk, nblk, dtype)
    rng = np.random.default_rng(0)
    s1 = jnp.asarray(rng.standard_normal(nblk), jnp.float32)
    s2 = jnp.asarray(rng.standard_normal(nblk), jnp.float32)
    got = block_combine(a, b, s1, s2, block_elems=block, interpret=True)
    want = ref.combine_ref(a, b, s1, s2, block)
    tol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got).astype(np.float32),
                               np.asarray(want).astype(np.float32),
                               rtol=tol, atol=tol * 10)


def test_segment_dots_respects_layer_boundaries():
    block = 1024
    seg = jnp.asarray(np.repeat([0, 0, 1, 2, 2, 2], block).astype(np.int32))
    a, b = data(6 * block, 42, jnp.float32)
    got = ops.adasum_segment_dots(a, b, seg, 3, block_elems=block)
    want = ref.segment_dots_ref(a, b, seg, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 6), st.integers(0, 2**31 - 1))
    def test_segment_combine_property(nblk, seed):
        """kernel combine == s1[seg]*a + s2[seg]*b for random segments."""
        block = 1024
        rng = np.random.default_rng(seed)
        nseg = rng.integers(1, nblk + 1)
        blk_seg = np.sort(rng.integers(0, nseg, size=nblk)).astype(np.int32)
        seg = jnp.asarray(np.repeat(blk_seg, block))
        a, b = data(nblk * block, seed, jnp.float32)
        s1 = jnp.asarray(rng.standard_normal(nseg), jnp.float32)
        s2 = jnp.asarray(rng.standard_normal(nseg), jnp.float32)
        got = ops.adasum_combine(a, b, s1, s2, seg, block_elems=block)
        want = s1[seg] * a + s2[seg] * b
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_interpret_autodetect():
    """interpret=None resolves per backend: interpreted off-TPU, compiled
    on TPU; an explicit flag always wins. On this container the
    auto-resolved path must match the pinned interpret=True result."""
    on_tpu = jax.default_backend() == "tpu"
    assert interpret_default() == (not on_tpu)
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    a, b = data(2048, 3, jnp.float32)
    auto = block_dots(a, b, block_elems=1024)          # interpret=None
    pinned = block_dots(a, b, block_elems=1024, interpret=True)
    if not on_tpu:
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(pinned))


def test_fp32_accumulation_beats_bf16_inputs():
    """§4.4.1: dot accumulation happens in fp32 even for bf16 gradients —
    the kernel's dots must be closer to the fp64 truth than a naive bf16
    accumulation."""
    n = 8192 * 4
    rng = np.random.default_rng(7)
    a64 = rng.standard_normal(n)
    b64 = rng.standard_normal(n)
    a = jnp.asarray(a64, jnp.float32).astype(jnp.bfloat16)
    b = jnp.asarray(b64, jnp.float32).astype(jnp.bfloat16)
    truth = np.vdot(np.asarray(a, np.float64), np.asarray(b, np.float64))
    kern = float(block_dots(a, b, interpret=True)[:, 0].sum())
    naive = float(jnp.sum((a * b).astype(jnp.bfloat16)
                          .astype(jnp.bfloat16)))
    assert abs(kern - truth) <= abs(naive - truth)
