"""Static-analysis subsystem (repro.analysis): golden known-bad
fixtures — each checker must FLAG its fixture — plus clean-path and
baseline-mutation coverage.

Everything here is trace/AST-only and device-count independent; the
full 32-device canonical comms matrix runs via `python -m
repro.analysis --all` in tools/ci.sh (one subprocess test mirrors a
slice of it).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import run_in_subprocess
from repro.analysis.hostsync import lint_source
from repro.analysis.jaxpr_utils import (acc_dtype_violations,
                                        collect_collectives,
                                        count_merge_reshapes, trace)
from repro.analysis.report import (diff_findings, diff_plans,
                                   findings_baseline)
from repro.analysis.retrace import signature_violations
from repro.parallel.sharding import local_shape, spec_violations


# ---------------------------------------------------------------- comms

def test_allgathering_combiner_flagged():
    """Golden fixture: a 'combiner' that all-gathers instead of psumming
    its dots must be reported by the collective scan. (axis_env traces
    under a fake 2-wide axis, so this holds at any host device count —
    a size-1 real axis would let jax elide the collective.)"""

    def bad_combine(x):
        g = jax.lax.all_gather(x, "data")
        return jnp.sum(g, axis=(0, 1))

    jaxpr = jax.make_jaxpr(bad_combine, axis_env=[("data", 2)])(
        jax.ShapeDtypeStruct((2, 8), jnp.float32))
    colls = collect_collectives(jaxpr)
    assert any(c["prim"] == "all_gather" for c in colls), colls


def test_psum_collected_with_axes():
    jaxpr = jax.make_jaxpr(
        lambda v: jax.lax.psum(v, ("data",)), axis_env=[("data", 2)])(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    colls = collect_collectives(jaxpr)
    assert [c["prim"] for c in colls] == ["psum"]
    assert colls[0]["axes"] == ("data",)
    assert colls[0]["manual"] is False  # not wrapped in shard_map here


def test_merge_reshape_flagged_outside_shard_map_only():
    """Collapsing non-unit dims of a global array (the `_split_lanes`
    replication hazard) counts; rank-increasing splits don't."""
    jp_bad = trace(lambda x: x.reshape(-1),
                   jax.ShapeDtypeStruct((4, 8), jnp.float32))
    assert count_merge_reshapes(jp_bad) == 1
    jp_ok = trace(lambda x: x.reshape(2, 2, 8),
                  jax.ShapeDtypeStruct((4, 8), jnp.float32))
    assert count_merge_reshapes(jp_ok) == 0
    jp_squeeze = trace(lambda x: x.reshape(4,),
                       jax.ShapeDtypeStruct((4, 1), jnp.float32))
    assert count_merge_reshapes(jp_squeeze) == 0


def test_comms_mutation_fires_baseline_diff():
    """Perturbing fusion_threshold_mb handling must change the comms
    plan report (bucket layout), so the baseline diff fails CI."""
    from repro.analysis.comms import check_comms

    clean, v0 = check_comms(archs=("qwen3-32b",), spans=(2,))
    assert v0 == [], v0
    mutated, _ = check_comms(archs=("qwen3-32b",), spans=(2,),
                             combine_overrides={
                                 "fusion_threshold_mb": 1e-5})
    drift = diff_plans(mutated, clean)
    assert drift, "threshold mutation did not change the comms plan"
    assert diff_plans(clean, clean) == []


def test_comms_canonical_matrix_subprocess():
    """One arch x spans {2, 8} on the canonical 32-device topology:
    every fused cell traces to exactly one psum per sharded bucket per
    level, reference cells to zero explicit collectives. (ci.sh runs
    the full 3-arch x {2,4,8} matrix via `python -m repro.analysis`.)"""
    out = run_in_subprocess(
        """
from repro.analysis.comms import check_comms
rep, viols = check_comms(archs=("mixtral-8x22b",), spans=(2, 8))
assert viols == [], viols
plans = rep["plans"]
assert rep["meta"]["mesh"] == {"data": 16, "model": 2}, rep["meta"]
for key, e in plans.items():
    assert e["all_gather"] == 0 and e["merge_reshapes"] == 0, (key, e)
    if "|fused|" in key:
        assert e["n_sharded_buckets"] > 0, (key, e)
        assert e["psums"] == e["levels"] * e["n_sharded_buckets"], (key, e)
    else:
        assert e["psums"] == 0, (key, e)
print("OK", len(plans))
""", devices=32, timeout=900)
    assert "OK 8" in out


# -------------------------------------------------------------- retrace

def test_drifting_decode_signature_flagged():
    steady = {"kv": jax.ShapeDtypeStruct((2, 4, 8), jnp.bfloat16),
              "pos": jax.ShapeDtypeStruct((4,), jnp.int32)}
    widened = {"kv": jax.ShapeDtypeStruct((2, 4, 8), jnp.float32),
               "pos": jax.ShapeDtypeStruct((4,), jnp.int32)}
    grown = {"kv": jax.ShapeDtypeStruct((2, 4, 9), jnp.bfloat16),
             "pos": jax.ShapeDtypeStruct((4,), jnp.int32)}
    bad = signature_violations(steady, [("widen", widened),
                                        ("grow", grown),
                                        ("ok", steady)])
    assert len(bad) == 2, bad
    assert any("widen" in b and "float32" in b for b in bad)
    assert any("grow" in b for b in bad)
    assert not any("ok" in b.split(":")[0] for b in bad)


def test_retrace_checker_clean_on_head():
    """eval_shape-only; holds under any device count."""
    from repro.analysis.retrace import check_arch

    entry = check_arch("qwen3-32b", "paged")
    assert entry["violations"] == [], entry
    assert entry["layout"] == "paged"
    # the quietly-dense ssm fallback is reported, not hidden
    entry = check_arch("rwkv6-7b", "paged")
    assert entry["violations"] == [], entry
    assert entry["layout"] == "dense"
    assert entry["dense_fallback_leaves"] > 0


# ------------------------------------------------------------- sharding

def test_bad_spec_naming_flagged():
    shapes = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    bad = spec_violations({"w": P("pod", None)}, shapes, {"data": 2})
    assert len(bad) == 1 and "unknown mesh axis" in bad[0][1], bad


def test_indivisible_and_duplicate_axis_flagged():
    shapes = {"w": jax.ShapeDtypeStruct((7, 8), jnp.float32)}
    bad = spec_violations({"w": P("data", None)}, shapes, {"data": 2})
    assert len(bad) == 1 and "not divisible" in bad[0][1], bad
    shapes = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    bad = spec_violations({"w": P(("data",), "data")}, shapes, {"data": 2})
    assert len(bad) == 1 and "more than one dim" in bad[0][1], bad


def test_local_shape():
    assert local_shape((8, 6), P("data", ("model", "pod")),
                       {"data": 2, "model": 3, "pod": 2}) == (4, 1)
    assert local_shape((8, 6), None, {"data": 2}) == (8, 6)


def test_rvh_gspecs_never_reuse_dp_axis():
    """The span==dp lane plan (caught by shardlint): the lane dim takes
    the DP axes, so the payload keeps only TP axes."""
    from repro.analysis.shardlint import check_sharding

    rep, viols = check_sharding(archs=("qwen3-32b",), spans=(16,))
    assert viols == [], viols


def test_acc_dtype_downcast_flagged():
    sds = jax.ShapeDtypeStruct((16, 16), jnp.bfloat16)
    # bf16 x bf16 dot accumulating in bf16: the silent-downcast fixture
    jaxpr = trace(lambda a, b: jnp.dot(a, b), sds, sds)
    bad_eqns = acc_dtype_violations(jaxpr, jnp.float32)
    assert bad_eqns and "bfloat16" in bad_eqns[0], bad_eqns
    # the same dot with fp32 accumulation is clean — and so is
    # jnp.sum(bf16, dtype=bf16), which internally upcasts to f32
    jaxpr = trace(lambda a, b: jnp.dot(a, b,
                                       preferred_element_type=jnp.float32),
                  sds, sds)
    assert acc_dtype_violations(jaxpr, jnp.float32) == []
    jaxpr = trace(lambda x: jnp.sum(x, dtype=jnp.bfloat16),
                  jax.ShapeDtypeStruct((16,), jnp.bfloat16))
    assert acc_dtype_violations(jaxpr, jnp.float32) == []


# ------------------------------------------------------------- hostsync

_HOT_FIXTURE = '''
import numpy as np

def make_decode_step(model):
    def step(params, tok, cache):
        print("tracing")
        vals.append(tok)
        return model(params, tok, cache)
    return step

def tick(self, logits, x):
    logits.block_until_ready()
    a = float(self._score(x))
    b = x.item()
    c = np.asarray(self._outs[0])
    d = int(x)            # host int conversion of a name: not flagged
    e = float(b)          # float() of a plain name: not flagged
    f = np.asarray(self._outs[0])  # lint: allow(host-pull)
    return a, b, c, d, e, f
'''


def test_hostsync_fixture_findings():
    findings = lint_source(_HOT_FIXTURE, "fixture.py")
    rules = [(f["rule"], f["code"]) for f in findings]
    assert ("block-until-ready", "logits.block_until_ready()") in rules
    assert any(r == "host-pull" and "self._score" in c for r, c in rules)
    assert any(r == "host-pull" and "x.item()" in c for r, c in rules)
    assert any(r == "host-pull" and "self._outs[0]" in c for r, c in rules)
    # traced-fn host mutation: print + closure .append inside the inner
    # fn returned by make_decode_step
    assert sum(1 for r, _ in rules if r == "host-mutation-in-jit") == 2
    # suppression + int()/float(name) exemptions
    assert sum(1 for r, c in rules
               if r == "host-pull" and "np.asarray" in c) == 1
    assert not any("int(x)" in c for _, c in rules)
    assert not any(c == "float(b)" for _, c in rules)


def test_hostsync_baseline_roundtrip():
    findings = lint_source(_HOT_FIXTURE, "fixture.py")
    base = findings_baseline(findings)
    assert diff_findings(findings, base) == []
    # a NEW finding (not in baseline) still fires
    extra = findings + [{"file": "fixture.py", "line": 99,
                         "rule": "host-pull", "code": "y.item()"}]
    assert len(diff_findings(extra, base)) == 1


def test_hostsync_head_clean_vs_baseline():
    """The repo's hot loops must introduce no NEW host syncs."""
    from pathlib import Path

    from repro.analysis.hostsync import check_hostsync
    from repro.analysis.report import load

    root = Path(__file__).resolve().parents[1]
    base = load(root / "tools/hostsync_baseline.json")
    assert base is not None, "tools/hostsync_baseline.json missing"
    _rep, viols = check_hostsync(root, base)
    assert viols == [], viols


# ------------------------------------------------------------------ CLI

def test_cli_help_runs_without_jax():
    import subprocess
    import sys

    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--help"],
        capture_output=True, text=True,
        env={**__import__("os").environ,
             "PYTHONPATH": str(__import__("pathlib").Path(
                 __file__).resolve().parents[1] / "src")})
    assert res.returncode == 0
    assert "--update-baselines" in res.stdout
