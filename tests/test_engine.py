"""Engine API tests: config round-trip, registry dispatch equivalence,
TrainSession fit/save/restore, and the make_runtime compat shim."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.engine import (EngineConfig, available_combiners, make_combiner,
                          register_combiner, registry_key)
from repro.core.combine import CombineConfig, build_combiner


# --------------------------------------------------------------- EngineConfig

class TestEngineConfig:
    def test_roundtrip_defaults(self):
        cfg = EngineConfig()
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg

    def test_roundtrip_nondefault(self):
        cfg = EngineConfig(arch="qwen3-32b", combine="sum", span=4,
                           backend="gspmd_tree", fsdp=True, lr=3e-4,
                           per_layer=False, acc_dtype="float64",
                           use_pallas=True, seq_len=128, global_batch=32,
                           ckpt_dir="/tmp/x", strict=True)
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg

    def test_from_dict_rejects_unknown_keys(self):
        with pytest.raises(ValueError, match="unknown EngineConfig keys"):
            EngineConfig.from_dict({"no_such_knob": 1})

    def test_preset_absorbs_policy_table(self):
        cfg = EngineConfig.preset("mixtral-8x22b")
        assert cfg.span == 2 and cfg.fsdp and cfg.accum_steps == 8
        assert cfg.param_dtype == "bfloat16"
        # presets stay round-trippable
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg

    def test_get_policy_matches_preset(self):
        from repro.parallel import get_policy
        pol = get_policy("qwen3-32b")
        cfg = EngineConfig.preset("qwen3-32b")
        assert pol.span == cfg.span == 4
        assert pol.backend == "gspmd_tree" and pol.accum_steps == 4

    def test_from_cli_roundtrip(self):
        cfg = EngineConfig.from_cli(
            ["--arch", "gemma-7b", "--reduced", "--steps", "7",
             "--seq", "64", "--batch", "8", "--combine", "sum",
             "--no-per-layer", "--acc-dtype", "float64", "--strict"])
        assert cfg.arch == "gemma-7b" and cfg.reduced
        assert cfg.steps == 7 and cfg.seq_len == 64 and cfg.global_batch == 8
        assert cfg.combine == "sum" and not cfg.per_layer
        assert cfg.acc_dtype == "float64" and cfg.strict
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg

    def test_validation_catches_bad_combos(self):
        with pytest.raises(ValueError, match="unknown combine op"):
            EngineConfig(combine="nope").validate()
        with pytest.raises(ValueError, match="divide dp"):
            EngineConfig(span=3).validate(dp_total=4)
        with pytest.raises(ValueError, match="not divisible by span"):
            EngineConfig(span=4, global_batch=6).validate(dp_total=4)
        with pytest.raises(ValueError, match="rvh"):
            EngineConfig(span=2, backend="rvh",
                         strict=True).validate(dp_total=4)
        # the same config is fine without strict (warns at build time)
        EngineConfig(span=2, backend="rvh",
                     global_batch=16).validate(dp_total=4)


# ------------------------------------------------------------------- registry

class TestRegistry:
    def test_builtin_entries(self):
        names = available_combiners()
        for n in ("sum", "mean", "adasum-gspmd", "adasum-rvh",
                  "adasum-linear"):
            assert n in names

    def test_registry_key_mapping(self):
        assert registry_key("sum") == "sum"
        assert registry_key("adasum", "gspmd_tree") == "adasum-gspmd"
        assert registry_key("adasum", "rvh") == "adasum-rvh"
        assert registry_key("adasum", "linear") == "adasum-linear"
        assert registry_key("adasum", "fused") == "adasum-fused"
        assert registry_key("custom-op", "") == "custom-op"

    def test_register_and_dispatch_custom(self):
        @register_combiner("test-first-lane", overwrite=True)
        def _first(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
            return lambda stacked: jax.tree.map(lambda x: x[0], stacked)

        c = make_combiner(CombineConfig(op="test-first-lane"))
        out = c({"w": jnp.arange(8.0).reshape(4, 2)})
        np.testing.assert_array_equal(np.asarray(out["w"]), [0.0, 1.0])

    def test_duplicate_registration_rejected(self):
        with pytest.raises(KeyError, match="already registered"):
            @register_combiner("sum")
            def _clash(cfg, **kw):   # pragma: no cover
                return lambda s: s

    def test_unknown_name_has_helpful_error(self):
        with pytest.raises(KeyError, match="registered"):
            make_combiner(CombineConfig(op="definitely-not-registered"))

    def test_registry_matches_reference_combiners(self):
        """Registry-dispatched outputs must be bit-identical to the
        reference implementations build_combiner used pre-refactor.
        gspmd_tree is pinned with fused=False (the opt-out keeps the
        exact per-leaf reference tree); the fused default is covered
        within fp32-accumulation tolerance below and exhaustively in
        tests/test_combine_fused.py."""
        from repro.core import adasum as A
        from repro.core.combine import (tree_combine_per_layer,
                                        tree_combine_whole)
        rng = np.random.default_rng(0)
        stacked = {"wq": jnp.asarray(rng.standard_normal((4, 8, 16)),
                                     jnp.float32),
                   "norm": jnp.asarray(rng.standard_normal((4, 8)),
                                       jnp.float32)}

        cases = [
            (CombineConfig(op="sum"),
             lambda s: A.sum_reduce(s, mean=False)),
            (CombineConfig(op="mean"),
             lambda s: A.sum_reduce(s, mean=True)),
            (CombineConfig(op="adasum", backend="gspmd_tree", fused=False),
             lambda s: tree_combine_per_layer(s, jnp.float32)),
            (CombineConfig(op="adasum", backend="gspmd_tree",
                           per_layer=False, fused=False),
             lambda s: tree_combine_whole(s, jnp.float32)),
            (CombineConfig(op="adasum", backend="linear"),
             lambda s: A.adasum_linear_reduce(
                 [jax.tree.map(lambda x, i=i: x[i], s) for i in range(4)],
                 per_layer=True, acc_dtype=jnp.float32)),
        ]
        for ccfg, ref_fn in cases:
            via_registry = make_combiner(ccfg)(stacked)
            via_legacy_api = build_combiner(ccfg)(stacked)
            ref = ref_fn(stacked)
            for k in stacked:
                a = np.asarray(via_registry[k])
                np.testing.assert_array_equal(a, np.asarray(ref[k]),
                                              err_msg=str(ccfg))
                np.testing.assert_array_equal(
                    a, np.asarray(via_legacy_api[k]), err_msg=str(ccfg))

        # the fused default (and the explicit fused backend) agree with
        # the reference within fp32-accumulation tolerance
        ref = tree_combine_per_layer(stacked, jnp.float32)
        for backend in ("gspmd_tree", "fused"):
            out = make_combiner(
                CombineConfig(op="adasum", backend=backend))(stacked)
            for k in stacked:
                np.testing.assert_allclose(
                    np.asarray(out[k]), np.asarray(ref[k]),
                    rtol=1e-5, atol=1e-5, err_msg=backend)

    def test_registry_rvh_matches_reference(self):
        """adasum-rvh through the registry == single-device tree reduce
        (8 simulated devices, subprocess per the test brief)."""
        run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import adasum
from repro.core.combine import CombineConfig, build_combiner
from repro.engine import make_combiner
from repro.launch.mesh import make_mesh_compat
np.random.seed(0)
mesh = make_mesh_compat((4, 2), ("data", "model"))
lanes = 4
tree = {"wq": np.random.randn(lanes, 8, 16).astype(np.float32),
        "wo": np.random.randn(lanes, 16, 8).astype(np.float32)}
specs = {"wq": P(None, "model"), "wo": P("model", None)}
sharded = {k: jax.device_put(v, NamedSharding(mesh, P(("data",), *(specs[k] or ()))))
           for k, v in tree.items()}
ccfg = CombineConfig(op="adasum", backend="rvh", span=lanes)
reg = jax.jit(make_combiner(ccfg, mesh=mesh, dp_axes=("data",),
                            leaf_specs=specs))(sharded)
leg = jax.jit(build_combiner(ccfg, mesh=mesh, dp_axes=("data",),
                             leaf_specs=specs))(sharded)
ref = adasum.adasum_tree_reduce(
    [{k: jnp.asarray(v[i]) for k, v in tree.items()} for i in range(lanes)])
for k in tree:
    np.testing.assert_array_equal(np.asarray(reg[k]), np.asarray(leg[k]))
    np.testing.assert_allclose(np.asarray(reg[k]), np.asarray(ref[k]),
                               rtol=2e-5, atol=2e-5)
print("OK")
""")


def test_policy_knobs_reach_combine_config():
    """per_layer / acc_dtype / use_pallas / compress / combine_point used
    to be silently dropped between RunPolicy and CombineConfig (§3.6
    ablation unreachable); they must plumb through now."""
    from repro.engine.build import _resolve_combine_cfg
    from repro.parallel.policy import RunPolicy
    rpol = RunPolicy(span=4, backend="gspmd_tree", per_layer=False,
                     acc_dtype="float64", use_pallas=True,
                     compress="int8", combine_point="pre")
    ccfg = _resolve_combine_cfg(rpol, span=4, dp_total=4, explicit=None,
                                strict=False)
    assert not ccfg.per_layer
    assert ccfg.acc_dtype == "float64" and ccfg.use_pallas
    assert ccfg.compress == "int8" and ccfg.point == "pre"
    assert ccfg.span == 4 and ccfg.backend == "gspmd_tree"


# --------------------------------------------------------------- TrainSession

class TestTrainSession:
    def test_fit_save_restore_resume(self, tmp_path):
        """2-step fit on an 8-device CPU mesh, then a fresh session must
        resume from the checkpoint and continue to step 4."""
        run_in_subprocess(rf"""
from repro.engine import EngineConfig, TrainSession
cfg = EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum",
                   seq_len=32, global_batch=8, ckpt_dir=r"{tmp_path}/ck",
                   ckpt_every=2, log_every=1)
s1 = TrainSession.from_config(cfg)
h1 = s1.fit(2)
assert [h["step"] for h in h1] == [0, 1], h1
assert s1.checkpoint.latest_step() == 2
s2 = TrainSession.from_config(cfg)
h2 = s2.fit(4)
assert [h["step"] for h in h2] == [2, 3], h2
import numpy as np
assert np.isfinite([h["loss"] for h in h1 + h2]).all()
print("OK")
""", devices=8, timeout=900)

    def test_step_api_and_custom_model(self):
        """step()/batch() drive a custom (non-registry) model on an
        explicit 1-device mesh (host device count varies across runners)."""
        from repro.configs.base import ModelConfig
        from repro.engine import TrainSession
        from repro.launch.mesh import make_local_mesh
        from repro.models import build_model
        mcfg = ModelConfig("tiny", "dense", 2, 32, 2, 1, 64, 97,
                           head_dim=16)
        cfg = EngineConfig(combine="adasum", seq_len=16, global_batch=4,
                           log_every=1)
        sess = TrainSession.from_config(
            cfg, model=build_model(mcfg, attn_chunk=16),
            mesh=make_local_mesh(1, 1), callbacks=[])
        m0 = sess.step(sess.batch(0))
        m1 = sess.step()      # auto-batch from the step counter
        assert np.isfinite(m0["loss"]) and np.isfinite(m1["loss"])
        assert int(jax.device_get(sess.state["step"])) == 2

    def test_missing_arch_and_model_raises(self):
        from repro.engine import TrainSession
        with pytest.raises(ValueError, match="arch is empty"):
            TrainSession.from_config(EngineConfig())


# ------------------------------------------------------- compat + strict mode

class TestCompatAndStrict:
    def test_make_runtime_shim_warns_and_works(self):
        from repro.configs.base import ModelConfig
        from repro.models import build_model
        from repro.launch.mesh import make_local_mesh
        from repro.parallel import make_runtime
        from repro.parallel.policy import RunPolicy
        mcfg = ModelConfig("tiny", "dense", 1, 32, 2, 1, 64, 97,
                           head_dim=16)
        model = build_model(mcfg, attn_chunk=16)
        mesh = make_local_mesh(1, 1)
        with pytest.warns(DeprecationWarning, match="make_runtime is "
                          "deprecated"):
            rt = make_runtime(model, mesh, RunPolicy(
                span=0, backend="gspmd_tree", optimizer="sgd"))
        state = rt.init_state(jax.random.key(0))
        toks = jnp.zeros((2, 16), jnp.int32)
        state, metrics = jax.jit(rt.train_step)(
            state, {"tokens": toks, "labels": toks})
        assert np.isfinite(float(metrics["loss"]))

    def test_rvh_fallback_warns_not_silent(self):
        """Asking for rvh with span != dp must WARN (old code silently
        switched backends) and hard-error under strict."""
        run_in_subprocess(r"""
import warnings
import pytest
from repro.configs.base import ModelConfig
from repro.engine import EngineWarning, build_runtime
from repro.models import build_model
from repro.launch.mesh import make_local_mesh
from repro.parallel.policy import RunPolicy
mcfg = ModelConfig("tiny", "dense", 1, 32, 2, 1, 64, 97, head_dim=16)
model = build_model(mcfg, attn_chunk=16)
mesh = make_local_mesh(2, 1)
rpol = RunPolicy(span=1, backend="rvh", optimizer="sgd")
with warnings.catch_warnings(record=True) as rec:
    warnings.simplefilter("always")
    rt = build_runtime(model, mesh, rpol)
msgs = [str(w.message) for w in rec
        if issubclass(w.category, EngineWarning)]
assert any("falling back" in m for m in msgs), msgs
assert rt.span == 1
try:
    build_runtime(model, mesh, rpol, strict=True)
except ValueError as e:
    assert "rvh" in str(e)
else:
    raise AssertionError("strict mode must raise on rvh fallback")
print("OK")
""", devices=2)

    def test_session_strict_rvh_raises(self):
        run_in_subprocess(r"""
from repro.engine import EngineConfig, TrainSession
try:
    TrainSession.from_config(EngineConfig(
        arch="gemma-7b", reduced=True, span=2, backend="rvh",
        seq_len=16, global_batch=8, strict=True))
except ValueError as e:
    assert "rvh" in str(e)
else:
    raise AssertionError("expected strict validation error")
print("OK")
""", devices=4)
